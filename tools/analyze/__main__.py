"""CLI for repro-lint.

Exit code is the number of NEW findings (capped at 100) — findings not
grandfathered by the committed baseline and not pragma-suppressed — so
the CI lint step fails exactly when a PR introduces a violation.

    python -m tools.analyze                      # check src/repro + tools
    python -m tools.analyze --list-rules         # document active rules
    python -m tools.analyze --format github      # CI annotations
    python -m tools.analyze --write-baseline     # grandfather the present
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.analyze import (
    DEFAULT_PATHS,
    iter_rules,
    load_baseline,
    new_findings,
    run_analysis,
    save_baseline,
)

REPO_ROOT = Path(__file__).resolve().parents[2]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze", description=__doc__.splitlines()[0]
    )
    ap.add_argument(
        "paths",
        nargs="*",
        default=None,
        help=f"files/dirs to check, relative to --root (default: {DEFAULT_PATHS})",
    )
    ap.add_argument(
        "--root",
        type=Path,
        default=REPO_ROOT,
        help="analysis root paths are resolved against (default: repo root)",
    )
    ap.add_argument(
        "--format",
        choices=("text", "github"),
        default="text",
        help="finding output style (github = workflow annotations)",
    )
    ap.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help="baseline file (default: tools/analyze/baseline.json under --root)",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="grandfather every current finding into the baseline and exit 0",
    )
    ap.add_argument(
        "--list-rules",
        action="store_true",
        help="print the active rule table and exit",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        rules = iter_rules()
        width = max(len(r.name) for r in rules)
        print(f"repro-lint: {len(rules)} active rules\n")
        for r in rules:
            print(f"  {r.name:<{width}}  {r.summary}")
        return 0

    baseline_path = args.baseline or (
        args.root / "tools" / "analyze" / "baseline.json"
    )
    findings = run_analysis(args.root, args.paths or None)

    if args.write_baseline:
        save_baseline(baseline_path, findings)
        print(
            f"repro-lint: baselined {len(findings)} finding(s) -> "
            f"{baseline_path}",
            file=sys.stderr,
        )
        return 0

    baseline = load_baseline(baseline_path)
    fresh = new_findings(findings, baseline)
    for f in fresh:
        print(f.github() if args.format == "github" else f.text())
    grandfathered = len(findings) - len(fresh)
    print(
        f"repro-lint: {len(fresh)} new finding(s), "
        f"{grandfathered} baselined",
        file=sys.stderr,
    )
    return min(len(fresh), 100)


if __name__ == "__main__":
    sys.exit(main())
