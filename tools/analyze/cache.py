"""Shared module cache: parse every file once, index pragmas.

The analyzer walks the tree a single time and hands every rule the same
parsed ``Module`` objects — rules never re-read or re-parse source. A
``Module`` carries the AST, the raw source lines (for snippets and
fingerprints), and the ``# repro: allow(<rule>)`` pragma index.

Pragma forms::

    DISPATCH["graph_calls"] += 1  # repro: allow(dispatch-in-traced) -- why
    # repro: allow(serve-wallclock) -- the clock seam itself
    dt = time.monotonic()

An inline pragma suppresses findings on its own line. A standalone
pragma (the comment is the whole line) also suppresses the line below
it, so multi-clause statements can carry an explanation without blowing
the line length. ``allow(*)`` suppresses every rule.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional

PRAGMA_RE = re.compile(r"#\s*repro:\s*allow\(([^)]*)\)")


@dataclasses.dataclass
class Module:
    """One parsed source file plus its pragma index."""

    path: Path  # absolute
    rel: str  # posix path relative to the analysis root
    source: str
    lines: List[str]
    tree: ast.Module
    # line number -> rule names allowed there ("*" = all rules)
    pragmas: Dict[int, FrozenSet[str]]

    def allows(self, line: int, rule: str) -> bool:
        rules = self.pragmas.get(line)
        return bool(rules) and ("*" in rules or rule in rules)

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""


def _parse_pragmas(source: str, lines: List[str]) -> Dict[int, FrozenSet[str]]:
    """Map line numbers to the rule names a pragma allows there.

    Uses the tokenizer (not a text scan) so pragma-looking strings inside
    string literals don't count.
    """
    out: Dict[int, FrozenSet[str]] = {}
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        tokens = []
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = PRAGMA_RE.search(tok.string)
        if not m:
            continue
        rules = frozenset(
            r.strip() for r in re.split(r"[,\s]+", m.group(1)) if r.strip()
        )
        if not rules:
            continue
        line = tok.start[0]
        out[line] = out.get(line, frozenset()) | rules
        text = lines[line - 1] if line <= len(lines) else ""
        if text.lstrip().startswith("#"):
            # standalone pragma: applies to the statement below, skipping
            # any continuation comment lines of the explanation
            nxt = line + 1
            while nxt <= len(lines) and lines[nxt - 1].lstrip().startswith("#"):
                nxt += 1
            out[nxt] = out.get(nxt, frozenset()) | rules
    return out


def load_module(path: Path, root: Path) -> Optional[Module]:
    """Parse one file; returns None when it cannot be read or parsed.

    Unparseable files are the ruff/E9 tier's problem, not this
    analyzer's — skipping keeps rule runs total.
    """
    try:
        source = path.read_text(encoding="utf-8")
        tree = ast.parse(source, filename=str(path))
    except (OSError, SyntaxError, ValueError):
        return None
    lines = source.splitlines()
    rel = path.resolve().relative_to(root.resolve()).as_posix()
    return Module(
        path=path,
        rel=rel,
        source=source,
        lines=lines,
        tree=tree,
        pragmas=_parse_pragmas(source, lines),
    )


def discover(root: Path, paths: List[str]) -> List[Module]:
    """Load every ``*.py`` under ``paths`` (files or directories), sorted."""
    seen: Dict[Path, None] = {}
    for p in paths:
        target = (root / p) if not Path(p).is_absolute() else Path(p)
        if target.is_file() and target.suffix == ".py":
            seen[target.resolve()] = None
        elif target.is_dir():
            for f in sorted(target.rglob("*.py")):
                if "__pycache__" in f.parts:
                    continue
                seen[f.resolve()] = None
    modules = []
    for f in seen:
        mod = load_module(f, root)
        if mod is not None:
            modules.append(mod)
    modules.sort(key=lambda m: m.rel)
    return modules
