"""Lightweight call graph with a "jit-reachable" closure.

Seeds are functions handed to the tracer: ``jax.jit(fn)`` /
``pjit(fn)`` arguments (including the ``functools.partial(jax.jit,
...)`` decorator spelling), jit-decorated defs, and kernel bodies
passed as the first argument of ``pl.pallas_call``. Reachability then
propagates along *name-matched* call edges: a call to ``run_aggregate_graph``
inside a traced function marks every def with that trailing name
reachable. This over-approximates (no type inference, no aliasing), so
very generic names are stoplisted rather than chased — a missed edge
only softens a warning-class rule, while a bogus edge sprays false
positives through host-side code.

Rules that scan "traced code" walk the *complete subtree* of each
reachable function (nested defs included, its own decorator list
excluded — decorators run at definition time on the host).
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Set, Tuple

from tools.analyze.cache import Module
from tools.analyze.registry import dotted_name, is_jit_call

# names too generic to chase across modules: matching them pulls in half
# the host-side tree
GENERIC_STOPLIST = {
    "get",
    "run",
    "close",
    "flush",
    "build",
    "init",
    "update",
    "step",
    "call",
    "main",
    "wrapper",
    "inner",
    "submit",
    "append",
    "extend",
    "add",
    "pop",
    "items",
    "keys",
    "values",
    "copy",
    "format",
    "join",
    "split",
    "read",
    "write",
    "open",
    "print",
}


@dataclasses.dataclass
class FunctionInfo:
    qualname: str  # e.g. "InferenceSession.__init__.fn"
    module_rel: str
    node: ast.AST  # FunctionDef | AsyncFunctionDef | Lambda
    calls: Set[str]  # trailing names of call targets in the body
    jit_seed: bool = False
    kernel_body: bool = False
    jit_reachable: bool = False

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]


def _call_names(node: ast.AST) -> Set[str]:
    """Trailing names of every call inside ``node``'s body."""
    names: Set[str] = set()
    body = getattr(node, "body", [])
    stmts = body if isinstance(body, list) else [body]
    for stmt in stmts:
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.Call):
                dn = dotted_name(sub.func)
                if dn:
                    names.add(dn[-1])
    return names


class _Collector(ast.NodeVisitor):
    def __init__(self, module: Module) -> None:
        self.module = module
        self.stack: List[str] = []
        self.functions: List[FunctionInfo] = []
        self._by_node: Dict[ast.AST, FunctionInfo] = {}

    def _record(self, node: ast.AST, name: str) -> FunctionInfo:
        qual = ".".join(self.stack + [name])
        info = FunctionInfo(
            qualname=qual,
            module_rel=self.module.rel,
            node=node,
            calls=_call_names(node),
        )
        self.functions.append(info)
        self._by_node[node] = info
        return info

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_def(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_def(node)

    def _visit_def(self, node: ast.AST) -> None:
        info = self._record(node, node.name)
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and is_jit_call(dec):
                info.jit_seed = True
            elif dotted_name(dec) and dotted_name(dec)[-1] in ("jit", "pjit"):
                info.jit_seed = True
        self.stack.append(node.name)
        for child in node.body:
            self.visit(child)
        self.stack.pop()

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._record(node, f"<lambda:{node.lineno}>")
        self.generic_visit(node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        for child in node.body:
            self.visit(child)
        self.stack.pop()


class CallGraph:
    """Name-indexed function table + reachability over all modules."""

    def __init__(self, modules: List[Module]) -> None:
        self.functions: List[FunctionInfo] = []
        self.by_node: Dict[int, FunctionInfo] = {}
        self._by_name: Dict[str, List[FunctionInfo]] = {}
        per_module: Dict[str, _Collector] = {}
        for mod in modules:
            col = _Collector(mod)
            for stmt in mod.tree.body:
                col.visit(stmt)
            per_module[mod.rel] = col
            for info in col.functions:
                self.functions.append(info)
                self.by_node[id(info.node)] = info
                self._by_name.setdefault(info.name, []).append(info)
        for mod in modules:
            self._mark_seeds(mod, per_module[mod.rel])
        self._propagate()

    # -------------------------------------------------------------- seeds
    def _mark_seeds(self, module: Module, col: _Collector) -> None:
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            dn = dotted_name(node.func)
            if is_jit_call(node) and dn and dn[-1] in ("jit", "pjit"):
                self._seed_arg(node, col, module, kernel=False)
            elif dn and dn[-1] == "pallas_call":
                self._seed_arg(node, col, module, kernel=True)

    def _seed_arg(
        self, call: ast.Call, col: _Collector, module: Module, kernel: bool
    ) -> None:
        args = list(call.args)
        if not args:
            return
        target = args[0]
        # unwrap functools.partial(kernel_fn, ...)
        if isinstance(target, ast.Call):
            tdn = dotted_name(target.func)
            if tdn and tdn[-1] == "partial" and target.args:
                target = target.args[0]
        if isinstance(target, ast.Lambda):
            info = col._by_node.get(target)
            if info is not None:
                self._mark(info, kernel)
            return
        tdn = dotted_name(target)
        if not tdn:
            return
        name = tdn[-1]
        # prefer defs in the same module; fall back to the global index
        local = [f for f in col.functions if f.name == name]
        for info in local or self._by_name.get(name, []):
            self._mark(info, kernel)

    def _mark(self, info: FunctionInfo, kernel: bool) -> None:
        if kernel:
            info.kernel_body = True
        else:
            info.jit_seed = True

    # ------------------------------------------------------- reachability
    def _propagate(self) -> None:
        work = [f for f in self.functions if f.jit_seed or f.kernel_body]
        for f in work:
            f.jit_reachable = True
        while work:
            fn = work.pop()
            for callee in fn.calls:
                if callee in GENERIC_STOPLIST:
                    continue
                for target in self._by_name.get(callee, []):
                    if not target.jit_reachable:
                        target.jit_reachable = True
                        work.append(target)

    # ------------------------------------------------------------- access
    def reachable_in(self, module: Module) -> List[FunctionInfo]:
        return [
            f
            for f in self.functions
            if f.module_rel == module.rel and f.jit_reachable
        ]

    def kernels_in(self, module: Module) -> List[FunctionInfo]:
        return [
            f
            for f in self.functions
            if f.module_rel == module.rel and f.kernel_body
        ]

    def info_for(self, node: ast.AST) -> FunctionInfo:
        return self.by_node[id(node)]


def walk_body(fn_node: ast.AST) -> Iterator[ast.AST]:
    """Every node in a function's body — nested defs included, the
    function's own decorator list and signature excluded."""
    body = getattr(fn_node, "body", [])
    stmts = body if isinstance(body, list) else [body]
    for stmt in stmts:
        yield from ast.walk(stmt)


def enclosing_functions(
    module: Module,
) -> List[Tuple[ast.AST, List[ast.AST]]]:
    """(function node, [its direct statements]) for every def in a module."""
    out = []
    for node in ast.walk(module.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.append((node, list(node.body)))
    return out
