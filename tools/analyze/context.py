"""Analysis context shared by every rule: modules, call graph, and the
declared ``DISPATCH`` counter keys with import-aware resolution.

``DISPATCH`` dicts are module-level literals (``core/flows.py`` and
``kernels/*/kernel.py``). A use site like ``flows.DISPATCH["traces"]``
is resolved through the using module's imports back to the declaring
module, so each module's key set is checked against the right
declaration; unresolvable references fall back to the union of all
declared keys (never a false positive, still catches typos).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.analyze.cache import Module
from tools.analyze.callgraph import CallGraph


def _module_name_to_rel(name: str, known: Set[str]) -> Optional[str]:
    """Dotted module name -> rel path, trying src/ layout first."""
    base = name.replace(".", "/")
    for cand in (f"src/{base}.py", f"{base}.py", f"src/{base}/__init__.py"):
        if cand in known:
            return cand
    return None


def _resolve_relative(module: Module, level: int, name: str) -> str:
    """``from .kernel import DISPATCH`` inside pkg/mod.py -> "pkg.kernel"."""
    parts = module.rel.rsplit(".py", 1)[0].split("/")
    if parts and parts[0] == "src":
        parts = parts[1:]
    # drop the module filename plus (level - 1) packages
    parts = parts[: len(parts) - level] if level <= len(parts) else []
    return ".".join(parts + [name]) if name else ".".join(parts)


class ImportMap:
    """Local binding name -> dotted module (or module attribute) source."""

    def __init__(self, module: Module) -> None:
        # name bound in this module -> dotted origin, e.g.
        #   "flows" -> "repro.core.flows"        (from repro.core import flows)
        #   "DISPATCH" -> "repro.core.flows.DISPATCH"
        self.bindings: Dict[str, str] = {}
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".")[0]
                    origin = alias.name if alias.asname else alias.name.split(".")[0]
                    self.bindings[bound] = origin
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    base = _resolve_relative(module, node.level, node.module or "")
                else:
                    base = node.module or ""
                for alias in node.names:
                    bound = alias.asname or alias.name
                    origin = f"{base}.{alias.name}" if base else alias.name
                    self.bindings[bound] = origin


class AnalysisContext:
    def __init__(self, modules: List[Module]) -> None:
        self.modules = modules
        self.by_rel: Dict[str, Module] = {m.rel: m for m in modules}
        self.callgraph = CallGraph(modules)
        self.imports: Dict[str, ImportMap] = {m.rel: ImportMap(m) for m in modules}
        # rel path -> keys of its module-level DISPATCH literal
        self.dispatch_decls: Dict[str, Set[str]] = {}
        for m in modules:
            keys = _declared_dispatch_keys(m)
            if keys is not None:
                self.dispatch_decls[m.rel] = keys
        self.dispatch_union: Set[str] = (
            set().union(*self.dispatch_decls.values())
            if self.dispatch_decls
            else set()
        )

    def dispatch_keys_for(self, module: Module, node: ast.AST) -> Optional[Set[str]]:
        """Declared keys governing a ``...DISPATCH[...]`` use site.

        ``node`` is the expression being subscripted (``Name`` or
        ``Attribute`` whose trailing attr is DISPATCH). Returns None when
        nothing is declared anywhere (rule stays silent).
        """
        if not self.dispatch_decls:
            return None
        known = set(self.by_rel)
        imap = self.imports[module.rel]
        if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
            origin = imap.bindings.get(node.value.id)
            if origin:
                rel = _module_name_to_rel(origin, known)
                if rel in self.dispatch_decls:
                    return self.dispatch_decls[rel]
        elif isinstance(node, ast.Name):
            if module.rel in self.dispatch_decls:
                return self.dispatch_decls[module.rel]
            origin = imap.bindings.get(node.id)
            if origin and origin.endswith(".DISPATCH"):
                rel = _module_name_to_rel(origin.rsplit(".", 1)[0], known)
                if rel in self.dispatch_decls:
                    return self.dispatch_decls[rel]
        return self.dispatch_union


def _declared_dispatch_keys(module: Module) -> Optional[Set[str]]:
    """Keys of a top-level ``DISPATCH = {...}`` literal, if present."""
    for stmt in module.tree.body:
        if not isinstance(stmt, ast.Assign):
            continue
        names = [t.id for t in stmt.targets if isinstance(t, ast.Name)]
        if "DISPATCH" not in names or not isinstance(stmt.value, ast.Dict):
            continue
        keys = set()
        for k in stmt.value.keys:
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                keys.add(k.value)
        return keys
    return None
