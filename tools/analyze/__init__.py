"""repro-lint: AST contract checks for this repo's invariants.

Run ``python -m tools.analyze`` from the repo root; see
``tools/README.md`` for the rule catalog, pragma syntax, and baseline
workflow.
"""

from pathlib import Path
from typing import List, Optional

import tools.analyze.rules  # noqa: F401  (registers every rule)
from tools.analyze.cache import Module, discover
from tools.analyze.context import AnalysisContext
from tools.analyze.registry import (
    Finding,
    fingerprints,
    iter_rules,
    load_baseline,
    new_findings,
    rule_names,
    save_baseline,
)

__all__ = [
    "AnalysisContext",
    "Finding",
    "Module",
    "discover",
    "fingerprints",
    "iter_rules",
    "load_baseline",
    "new_findings",
    "rule_names",
    "run_analysis",
    "save_baseline",
]

DEFAULT_PATHS = ["src/repro", "tools"]


def run_analysis(root: Path, paths: Optional[List[str]] = None) -> List[Finding]:
    """All unsuppressed findings for the tree under ``root``.

    The call graph spans every loaded module, so reachability crosses
    module boundaries; pragma-suppressed findings are already dropped.
    """
    modules = discover(root, paths or DEFAULT_PATHS)
    ctx = AnalysisContext(modules)
    findings: List[Finding] = []
    for rule in iter_rules():
        for module in modules:
            for f in rule.check(module, ctx):
                if not module.allows(f.line, f.rule):
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings
