"""Rule registry, findings, and the committed baseline.

Mirrors the repo's model/dataset registries: rules self-register via the
``@register_rule`` decorator at import time, the driver iterates
``iter_rules()``. A ``Finding`` fingerprints on the *content* of its
line (rule + file + snippet hash + occurrence index), so pure line
drift — code added above a baselined finding — does not resurrect it,
while editing the flagged line itself does.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
from collections import Counter
from pathlib import Path
from typing import TYPE_CHECKING, Dict, Iterable, Iterator, List, Tuple, Type

if TYPE_CHECKING:  # pragma: no cover - typing only
    from tools.analyze.cache import Module
    from tools.analyze.context import AnalysisContext

BASELINE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # rel posix path
    line: int
    col: int
    message: str
    snippet: str = ""

    def content_key(self) -> str:
        digest = hashlib.sha1(self.snippet.strip().encode("utf-8")).hexdigest()
        return f"{self.rule}:{self.path}:{digest[:12]}"

    def text(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def github(self) -> str:
        return (
            f"::error file={self.path},line={self.line},col={self.col},"
            f"title=repro-lint({self.rule})::{self.message}"
        )


class Rule:
    """One contract checker. Subclasses set ``name``/``summary`` and
    implement ``check`` yielding findings for a single module."""

    name: str = ""
    summary: str = ""

    def check(self, module: "Module", ctx: "AnalysisContext") -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, module: "Module", node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0) + 1
        return Finding(
            rule=self.name,
            path=module.rel,
            line=line,
            col=col,
            message=message,
            snippet=module.snippet(line),
        )


_RULES: Dict[str, Rule] = {}


def register_rule(cls: Type[Rule]) -> Type[Rule]:
    if not cls.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if cls.name in _RULES:
        raise ValueError(f"duplicate rule name {cls.name!r}")
    _RULES[cls.name] = cls()
    return cls


def iter_rules() -> List[Rule]:
    return [_RULES[name] for name in sorted(_RULES)]


def rule_names() -> List[str]:
    return sorted(_RULES)


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


def fingerprints(findings: Iterable[Finding]) -> Counter:
    """Multiset of content keys — duplicates of the same line count."""
    return Counter(f.content_key() for f in findings)


def new_findings(findings: List[Finding], baseline: Counter) -> List[Finding]:
    """Findings beyond what the baseline grandfathers, content-matched.

    With N identical occurrences baselined and N+K present, the K
    later-in-file ones are new.
    """
    budget = Counter(baseline)
    fresh = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col)):
        key = f.content_key()
        if budget[key] > 0:
            budget[key] -= 1
        else:
            fresh.append(f)
    return fresh


def load_baseline(path: Path) -> Counter:
    if not path.exists():
        return Counter()
    data = json.loads(path.read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path}")
    return Counter(data.get("fingerprints", {}))


def save_baseline(path: Path, findings: List[Finding]) -> Counter:
    counts = fingerprints(findings)
    payload = {
        "version": BASELINE_VERSION,
        "fingerprints": dict(sorted(counts.items())),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return counts


# ---------------------------------------------------------------------------
# shared AST helpers used by several rule families
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Tuple[str, ...]:
    """``jax.lax.fori_loop`` -> ("jax", "lax", "fori_loop"); () if the
    base is not a plain name chain (calls/subscripts terminate it)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def root_name(node: ast.AST) -> str:
    """Leftmost plain name of an attribute/subscript/call chain, or ""."""
    while True:
        if isinstance(node, ast.Attribute):
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        else:
            break
    return node.id if isinstance(node, ast.Name) else ""


def is_jit_call(node: ast.Call) -> bool:
    """``jax.jit(...)`` / ``jit(...)`` / ``pjit(...)`` and the
    ``functools.partial(jax.jit, ...)`` decorator spelling."""
    dn = dotted_name(node.func)
    if dn and dn[-1] in ("jit", "pjit"):
        return True
    if dn and dn[-1] == "partial" and node.args:
        first = node.args[0]
        fdn = dotted_name(first)
        return bool(fdn) and fdn[-1] in ("jit", "pjit")
    return False
