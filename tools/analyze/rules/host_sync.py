"""Host-sync hazards in serving steady-state code.

Scope: ``src/repro/serve/`` and ``src/repro/core/session.py`` — the
per-request hot path. A ``.block_until_ready()`` / ``.item()`` /
``float()`` / ``np.asarray()`` on a jax value forces a device→host
round trip and serializes the pipeline; the serve design funnels every
sanctioned sync through one point (``ServeFrontend._resolve``). New
sync sites need a pragma arguing why.

Jax-valued names are tracked with a one-pass, order-aware dataflow
sketch per function: assignments from jnp/jax calls (or known
session/executable dispatches) mark names device-resident; assignments
from np.* or constants clear them. ``jax.tree_util.tree_map`` lambda
parameters count as jax-valued inside the lambda.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from tools.analyze.cache import Module
from tools.analyze.context import AnalysisContext
from tools.analyze.registry import Finding, Rule, dotted_name, register_rule

SCOPE_PREFIX = "src/repro/serve/"
SCOPE_FILES = {"src/repro/core/session.py"}

JAX_ROOTS = {"jnp", "jax"}
DEVICE_CALL_ATTRS = {
    "query",
    "query_ego",
    "apply",
    "checkout",
    "compile_query",
    "compile_ego",
}
SYNC_ATTRS = {"item", "tolist", "block_until_ready", "device_get"}
NP_SYNC_FNS = {"asarray", "array", "copy"}
BUILTIN_SYNC = {"float", "int", "bool"}


def _in_scope(module: Module) -> bool:
    return module.rel.startswith(SCOPE_PREFIX) or module.rel in SCOPE_FILES


def _is_jax_expr(node: ast.AST, jax_names: Set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in jax_names
    if isinstance(node, (ast.Subscript, ast.Attribute)):
        return _is_jax_expr(node.value, jax_names)
    if isinstance(node, ast.Call):
        dn = dotted_name(node.func)
        if dn and dn[0] in JAX_ROOTS:
            return True
        if dn and dn[-1] in DEVICE_CALL_ATTRS:
            return True
        if dn and len(dn) == 1 and dn[0] in jax_names:
            return True  # exe(...) where exe came from compile_*
        # x.astype(...) etc. on a jax value stays jax
        if isinstance(node.func, ast.Attribute):
            return _is_jax_expr(node.func.value, jax_names)
    if isinstance(node, ast.BinOp):
        return _is_jax_expr(node.left, jax_names) or _is_jax_expr(node.right, jax_names)
    return False


def _target_names(target: ast.AST) -> Iterator[str]:
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _target_names(elt)
    elif isinstance(target, ast.Starred):
        yield from _target_names(target.value)


@register_rule
class ServeHostSync(Rule):
    name = "serve-host-sync"
    summary = "device→host sync (np.asarray/.item()/float) on a jax value"

    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        if not _in_scope(module):
            return
        seen: Set[tuple] = set()
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for f in self._check_fn(module, node):
                    key = (f.line, f.col)
                    if key not in seen:
                        seen.add(key)
                        yield f

    def _check_fn(self, module: Module, fn: ast.AST) -> Iterator[Finding]:
        jax_names: Set[str] = set()
        # one pass in source order: track bindings, flag syncs as seen
        for stmt in fn.body:
            yield from self._walk_stmt(module, stmt, jax_names)

    def _walk_stmt(
        self, module: Module, stmt: ast.AST, jax_names: Set[str]
    ) -> Iterator[Finding]:
        # loop/comprehension targets bind before their element
        # expressions evaluate — collect them first so `np.asarray(l)
        # for l in leaves` sees `l` as jax-valued
        for sub in ast.walk(stmt):
            if isinstance(sub, (ast.For, ast.AsyncFor, ast.comprehension)):
                if _is_jax_expr(sub.iter, jax_names):
                    jax_names.update(_target_names(sub.target))
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.Call):
                yield from self._check_call(module, sub, jax_names)
            elif isinstance(sub, ast.Assign):
                self._bind(sub.targets, sub.value, jax_names)
            elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
                self._bind([sub.target], sub.value, jax_names)

    def _bind(self, targets, value: ast.AST, jax_names: Set[str]) -> None:
        names = [n for t in targets for n in _target_names(t)]
        vdn = dotted_name(value.func) if isinstance(value, ast.Call) else ()
        host_valued = bool(vdn) and vdn[0] in ("np", "numpy")
        if _is_jax_expr(value, jax_names) and not host_valued:
            jax_names.update(names)
        else:
            jax_names.difference_update(names)

    def _check_call(
        self, module: Module, call: ast.Call, jax_names: Set[str]
    ) -> Iterator[Finding]:
        dn = dotted_name(call.func)
        # tree_map(lambda l: ..., params): lambda params are jax-valued
        if dn and dn[-1] == "tree_map" and call.args:
            lam = call.args[0]
            if isinstance(lam, ast.Lambda):
                inner = set(jax_names)
                inner.update(a.arg for a in lam.args.args)
                yield from self._scan_expr(module, lam.body, inner)
        if not dn:
            return
        arg = call.args[0] if call.args else None
        if dn[0] in ("np", "numpy") and dn[-1] in NP_SYNC_FNS:
            if arg is not None and _is_jax_expr(arg, jax_names):
                yield self._sync(module, call, ".".join(dn))
        elif len(dn) == 1 and dn[0] in BUILTIN_SYNC:
            if arg is not None and _is_jax_expr(arg, jax_names):
                yield self._sync(module, call, dn[0])
        elif dn[0] == "jax" and dn[-1] in ("block_until_ready", "device_get"):
            yield self._sync(module, call, ".".join(dn))
        elif dn[-1] in SYNC_ATTRS and isinstance(call.func, ast.Attribute):
            if _is_jax_expr(call.func.value, jax_names):
                yield self._sync(module, call, ".".join(dn))

    def _scan_expr(
        self, module: Module, expr: ast.AST, jax_names: Set[str]
    ) -> Iterator[Finding]:
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Call):
                yield from self._check_call(module, sub, jax_names)

    def _sync(self, module: Module, call: ast.Call, what: str) -> Finding:
        return self.finding(
            module,
            call,
            f"{what} forces a device→host sync on the serve hot path: "
            "it stalls the dispatch pipeline — keep values on device "
            "and sync only at the sanctioned resolve point (or pragma "
            "with a justification)",
        )
