"""DISPATCH counter discipline.

The dispatch counters (``core/flows.py`` DISPATCH, the kernel module's
own DISPATCH) back the repo's zero-dispatch / single-launch invariants:
tests and benchmarks snapshot them around a call and assert deltas. A
typo'd key silently creates a new counter that no invariant watches; an
increment of a *runtime* key inside traced code fires once at trace
time and never again, so the invariant it feeds goes blind.

Trace-time keys — counters that by design tick during tracing to
assert trace counts — are exempt inside traced code:
``traces``, ``grouped_traces``, ``sharded_traces``, ``pallas_calls``,
``ego_traces``.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional, Set

from tools.analyze.cache import Module
from tools.analyze.callgraph import walk_body
from tools.analyze.context import AnalysisContext
from tools.analyze.registry import Finding, Rule, register_rule

TRACE_TIME_KEYS = {
    "traces",
    "grouped_traces",
    "sharded_traces",
    "pallas_calls",
    "ego_traces",
}


def _dispatch_subscript(node: ast.AST) -> Optional[ast.Subscript]:
    """Matches ``DISPATCH[...]`` / ``flows.DISPATCH[...]`` / etc."""
    if not isinstance(node, ast.Subscript):
        return None
    base = node.value
    if isinstance(base, ast.Name) and base.id == "DISPATCH":
        return node
    if isinstance(base, ast.Attribute) and base.attr == "DISPATCH":
        return node
    return None


def _const_key(sub: ast.Subscript) -> Optional[str]:
    sl = sub.slice
    if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
        return sl.value
    return None


@register_rule
class DispatchUnknownKey(Rule):
    name = "dispatch-unknown-key"
    summary = "DISPATCH[...] key not declared in the owning DISPATCH dict"

    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        for node in ast.walk(module.tree):
            sub = _dispatch_subscript(node)
            if sub is None:
                continue
            key = _const_key(sub)
            if key is None:
                continue
            declared = ctx.dispatch_keys_for(module, sub.value)
            if declared is None or key in declared:
                continue
            yield self.finding(
                module,
                sub,
                f"DISPATCH key {key!r} is not declared in the owning "
                "DISPATCH dict — a typo here silently detaches the "
                "counter from every invariant that watches it",
            )


@register_rule
class DispatchInTraced(Rule):
    name = "dispatch-in-traced"
    summary = "runtime DISPATCH counter incremented inside traced code"

    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        seen: Set[int] = set()
        for info in ctx.callgraph.reachable_in(module):
            for node in walk_body(info.node):
                if not isinstance(node, (ast.AugAssign, ast.Assign)):
                    continue
                targets = (
                    [node.target]
                    if isinstance(node, ast.AugAssign)
                    else node.targets
                )
                for t in targets:
                    sub = _dispatch_subscript(t)
                    if sub is None or id(sub) in seen:
                        continue
                    seen.add(id(sub))
                    key = _const_key(sub)
                    if key in TRACE_TIME_KEYS:
                        continue
                    yield self.finding(
                        module,
                        node,
                        f"DISPATCH[{key!r}] written inside traced code "
                        f"({info.qualname}): side effects run once at "
                        "trace time, so the counter stops tracking real "
                        "dispatches — count on the host, outside jit",
                    )
