"""Trace-purity / retrace-hazard rules.

The session contract (``core/session.py``, ``DISPATCH["traces"]``
asserts in tests) is trace-once: every ``jax.jit`` is created at build
time, cached, and reused. Creating a jit inside a loop or per-call
function re-hashes statics every iteration and at worst retraces;
branching Python control flow on traced values fails at trace time on
the abstract value — both are exactly the class of bug the zero-retrace
benchmarks only catch when a benchmark happens to walk the new path.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, Set

from tools.analyze.cache import Module
from tools.analyze.callgraph import walk_body
from tools.analyze.context import AnalysisContext
from tools.analyze.registry import (
    Finding,
    Rule,
    dotted_name,
    is_jit_call,
    register_rule,
    root_name,
)


def _jit_creations(node: ast.AST) -> Iterator[ast.Call]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and is_jit_call(sub):
            dn = dotted_name(sub.func)
            if dn and dn[-1] in ("jit", "pjit"):
                yield sub


@register_rule
class JitInLoop(Rule):
    name = "jit-in-loop"
    summary = "jax.jit/pjit created inside a loop body (retrace hazard)"

    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
                continue
            for stmt in node.body + node.orelse:
                for call in _jit_creations(stmt):
                    yield self.finding(
                        module,
                        call,
                        "jax.jit created inside a loop: every iteration "
                        "re-wraps (and can retrace) — hoist the jit out and "
                        "reuse one compiled callable",
                    )


@register_rule
class JitInTraced(Rule):
    name = "jit-in-traced"
    summary = "jax.jit/pjit created inside jit-reachable (traced) code"

    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        seen: Set[int] = set()
        for info in ctx.callgraph.reachable_in(module):
            for call in _jit_creations_in_body(info.node):
                if id(call) in seen:
                    continue
                seen.add(id(call))
                yield self.finding(
                    module,
                    call,
                    f"jit created inside traced code ({info.qualname}): "
                    "nested jit wrapping at trace time is a retrace/"
                    "cache-miss hazard — build executables at session "
                    "compile time",
                )


def _jit_creations_in_body(fn_node: ast.AST) -> Iterator[ast.Call]:
    for sub in walk_body(fn_node):
        if isinstance(sub, ast.Call) and is_jit_call(sub):
            dn = dotted_name(sub.func)
            if dn and dn[-1] in ("jit", "pjit"):
                yield sub


_TRACED_ROOTS = {"jnp", "lax"}


def _is_traced_value_expr(node: ast.AST) -> bool:
    """Heuristic: the expression calls into jnp/jax.lax, so under jit it
    yields a tracer — branching Python control flow on it explodes."""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        dn = dotted_name(sub.func)
        if not dn:
            continue
        if dn[0] in _TRACED_ROOTS:
            return True
        if dn[0] == "jax" and len(dn) > 1 and dn[1] in ("lax", "numpy", "nn"):
            return True
    return False


@register_rule
class TracedBranch(Rule):
    name = "traced-python-branch"
    summary = "Python if/while on a jnp/lax value inside traced code"

    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        seen: Set[int] = set()
        for info in ctx.callgraph.reachable_in(module):
            for sub in walk_body(info.node):
                if not isinstance(sub, (ast.If, ast.While, ast.IfExp)):
                    continue
                if id(sub) in seen or not _is_traced_value_expr(sub.test):
                    continue
                seen.add(id(sub))
                yield self.finding(
                    module,
                    sub,
                    f"Python branch on a traced (jnp/lax) value in "
                    f"{info.qualname}: fails at trace time or silently "
                    "freezes one path — use jnp.where / lax.cond",
                )


_LITERAL_UNHASHABLE = (
    ast.List,
    ast.Dict,
    ast.Set,
    ast.ListComp,
    ast.SetComp,
    ast.DictComp,
)
_UNHASHABLE_CTORS = {"list", "dict", "set"}


@register_rule
class JitUnhashableStatic(Rule):
    name = "jit-unhashable-static"
    summary = "jit-wrapped local closes over a list/dict/set binding"

    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        for outer in ast.walk(module.tree):
            if not isinstance(outer, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            local_defs = {
                s.name: s
                for s in ast.walk(outer)
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))
                and s is not outer
            }
            unhashable = _unhashable_bindings(outer)
            if not unhashable:
                continue
            for call in _jit_creations(outer):
                target = call.args[0] if call.args else None
                if isinstance(target, ast.Name) and target.id in local_defs:
                    fn_node = local_defs[target.id]
                elif isinstance(target, ast.Lambda):
                    fn_node = target
                else:
                    continue
                for free in sorted(_free_names(fn_node) & set(unhashable)):
                    yield self.finding(
                        module,
                        call,
                        f"jit-wrapped {getattr(target, 'id', '<lambda>')} "
                        f"closes over {free!r}, bound to an unhashable "
                        "list/dict/set: hashing for the jit cache fails (or "
                        "retraces) — use a tuple or pass it as an argument",
                    )


def _unhashable_bindings(outer: ast.AST) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    for sub in ast.walk(outer):
        if isinstance(sub, ast.Assign):
            value, targets = sub.value, sub.targets
        elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
            value, targets = sub.value, [sub.target]
        else:
            continue
        is_bad = isinstance(value, _LITERAL_UNHASHABLE) or (
            isinstance(value, ast.Call)
            and root_name(value.func) in _UNHASHABLE_CTORS
        )
        if not is_bad:
            continue
        for t in targets:
            if isinstance(t, ast.Name):
                out[t.id] = value
    return out


def _free_names(fn_node: ast.AST) -> Set[str]:
    bound: Set[str] = set()
    args = fn_node.args
    for a in args.args + args.posonlyargs + args.kwonlyargs:
        bound.add(a.arg)
    if args.vararg:
        bound.add(args.vararg.arg)
    if args.kwarg:
        bound.add(args.kwarg.arg)
    loaded: Set[str] = set()
    for sub in walk_body(fn_node):
        if isinstance(sub, ast.Name):
            if isinstance(sub.ctx, ast.Load):
                loaded.add(sub.id)
            else:
                bound.add(sub.id)
    return loaded - bound
