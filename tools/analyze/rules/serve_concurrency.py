"""Serve-plane concurrency contracts.

The serving stack's determinism rests on the injected clock/executor
seam (``serve/clock.py``): ``FakeClock`` load tests only stay sleep-free
if nothing in ``serve/`` touches the wall clock directly. Its liveness
rests on never blocking while holding a lock — the collector/stepper
handshake and the no-stranded-futures contract both assume lock bodies
are O(bookkeeping).
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from tools.analyze.cache import Module
from tools.analyze.context import AnalysisContext
from tools.analyze.registry import Finding, Rule, dotted_name, register_rule

SERVE_PREFIX = "src/repro/serve/"

# attribute calls that park the calling thread (or dispatch work and
# wait for it) — never while holding a lock
BLOCKING_ATTRS = {
    "sleep",
    "result",
    "join",
    "acquire",
    "wait",
    "wait_for",
    "block_until_ready",
    "query",
    "query_ego",
    "prewarm",
    "drain",
    "flush",
}
# condition-variable methods that are *correct* on the held object
COND_METHODS = {"wait", "wait_for", "notify", "notify_all"}


def _in_serve(module: Module) -> bool:
    return module.rel.startswith(SERVE_PREFIX)


@register_rule
class ServeWallclock(Rule):
    name = "serve-wallclock"
    summary = "raw time.*/threading.Timer in serve/ (bypasses clock seam)"

    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        if not _in_serve(module):
            return
        for node in ast.walk(module.tree):
            dn = dotted_name(node) if isinstance(node, ast.Attribute) else ()
            if dn and dn[0] == "time" and len(dn) > 1:
                yield self.finding(
                    module,
                    node,
                    f"raw {'.'.join(dn)} in serve/: all timing must go "
                    "through the injected Clock seam so FakeClock load "
                    "tests stay deterministic and sleep-free",
                )
            elif isinstance(node, ast.Call) and dotted_name(node.func)[-1:] == (
                "Timer",
            ):
                yield self.finding(
                    module,
                    node,
                    "threading.Timer in serve/: schedule through the "
                    "Clock/executor seam instead",
                )


def _lock_like(expr: ast.AST) -> Optional[str]:
    """A with-context that reads like a lock/condition; returns its
    dump-key for identity comparison."""
    dn = dotted_name(expr)
    if not dn:
        return None
    last = dn[-1].lower()
    if "lock" in last or "cond" in last or "mutex" in last:
        return ast.dump(expr)
    return None


@register_rule
class ServeLockBlocking(Rule):
    name = "serve-lock-held-blocking"
    summary = "blocking call while holding a lock in serve/"

    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        if not _in_serve(module):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.With, ast.AsyncWith)):
                continue
            held = [
                key
                for item in node.items
                if (key := _lock_like(item.context_expr)) is not None
            ]
            if not held:
                continue
            for stmt in node.body:
                yield from self._scan(module, stmt, held)

    def _scan(self, module: Module, stmt: ast.AST, held: list) -> Iterator[Finding]:
        for sub in ast.walk(stmt):
            if not isinstance(sub, ast.Call):
                continue
            dn = dotted_name(sub.func)
            if not dn or dn[-1] not in BLOCKING_ATTRS:
                continue
            if dn[-1] in COND_METHODS and isinstance(sub.func, ast.Attribute):
                # cond.wait()/wait_for() on the HELD condition releases it
                # while parked — the one sanctioned blocking idiom
                if ast.dump(sub.func.value) in held:
                    continue
            yield self.finding(
                module,
                sub,
                f"{'.'.join(dn)} called while a lock is held: blocking "
                "under a lock stalls every other serve thread and can "
                "deadlock the collector/stepper handshake — move the "
                "blocking call outside the lock body",
            )
