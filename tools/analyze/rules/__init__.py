"""Rule modules self-register on import (``@register_rule``)."""

from tools.analyze.rules import (  # noqa: F401
    dispatch_keys,
    host_sync,
    kernel_hygiene,
    serve_concurrency,
    trace_purity,
)
