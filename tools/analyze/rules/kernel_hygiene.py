"""Pallas kernel-body hygiene.

Kernel bodies (functions handed to ``pl.pallas_call``, all living in
``kernels/*/kernel.py``) compile to Mosaic/Triton — host callbacks
don't exist there, Python control flow on ref *values* is resolved at
trace time against abstract values, and any call outside the small
blessed surface (jnp / jax.lax / pl / pltpu / this module's own
helpers) either fails to lower or, worse, silently runs at trace time.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from tools.analyze.cache import Module
from tools.analyze.callgraph import FunctionInfo, walk_body
from tools.analyze.context import AnalysisContext
from tools.analyze.registry import (
    Finding,
    Rule,
    dotted_name,
    register_rule,
    root_name,
)

KERNEL_PATH_RE = ("src/repro/kernels/", "/kernel.py")

ALLOWED_ROOTS = {"jnp", "jax", "pl", "pltpu", "lax", "functools"}
ALLOWED_BUILTINS = {
    "range",
    "len",
    "min",
    "max",
    "abs",
    "int",
    "float",
    "bool",
    "enumerate",
    "zip",
    "tuple",
    "isinstance",
    "getattr",
    "partial",
}
HOST_CALL_NAMES = {"print", "breakpoint", "input", "open"}
HOST_ROOTS = {"np", "numpy", "os", "sys", "time", "logging"}


def _is_kernel_module(module: Module) -> bool:
    pre, suf = KERNEL_PATH_RE
    return module.rel.startswith(pre) and module.rel.endswith(suf)


def _local_names(fn_node: ast.AST) -> Set[str]:
    """Params, assigned names, loop vars, nested defs — in-kernel names."""
    names: Set[str] = set()
    args = fn_node.args
    for a in args.args + args.posonlyargs + args.kwonlyargs:
        names.add(a.arg)
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    for sub in walk_body(fn_node):
        if isinstance(sub, ast.Name) and isinstance(
            sub.ctx, (ast.Store, ast.Del)
        ):
            names.add(sub.id)
        elif isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(sub.name)
    return names


def _module_level_names(module: Module) -> Set[str]:
    """Top-level defs, assignments, and imported names of the module."""
    names: Set[str] = set()
    for stmt in module.tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            names.add(stmt.name)
        elif isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(stmt, ast.ImportFrom):
            for alias in stmt.names:
                names.add(alias.asname or alias.name)
        elif isinstance(stmt, ast.Import):
            for alias in stmt.names:
                names.add(alias.asname or alias.name.split(".")[0])
    return names


class _KernelRule(Rule):
    def check(self, module: Module, ctx: AnalysisContext) -> Iterator[Finding]:
        if not _is_kernel_module(module):
            return
        for info in ctx.callgraph.kernels_in(module):
            yield from self.check_kernel(module, ctx, info)

    def check_kernel(
        self, module: Module, ctx: AnalysisContext, info: FunctionInfo
    ) -> Iterator[Finding]:
        raise NotImplementedError


@register_rule
class KernelHostCallback(_KernelRule):
    name = "kernel-host-callback"
    summary = "host callback / IO / numpy call inside a Pallas kernel body"

    def check_kernel(
        self, module: Module, ctx: AnalysisContext, info: FunctionInfo
    ) -> Iterator[Finding]:
        for node in walk_body(info.node):
            if not isinstance(node, ast.Call):
                continue
            dn = dotted_name(node.func)
            if not dn:
                continue
            bad = (
                (len(dn) == 1 and dn[0] in HOST_CALL_NAMES)
                or dn[0] in HOST_ROOTS
                or "callback" in dn[-1]
                or (dn[0] == "jax" and len(dn) > 1 and dn[1] == "debug")
            )
            if bad:
                yield self.finding(
                    module,
                    node,
                    f"host-side call {'.'.join(dn)} inside kernel body "
                    f"{info.qualname}: kernels lower to Mosaic — host "
                    "callbacks/IO/numpy cannot run there",
                )


@register_rule
class KernelRefBranch(_KernelRule):
    name = "kernel-ref-branch"
    summary = "Python if/while on ref values inside a Pallas kernel body"

    def check_kernel(
        self, module: Module, ctx: AnalysisContext, info: FunctionInfo
    ) -> Iterator[Finding]:
        params = _param_names(info.node)
        for node in walk_body(info.node):
            if not isinstance(node, (ast.If, ast.While, ast.IfExp)):
                continue
            if _reads_ref(node.test, params):
                yield self.finding(
                    module,
                    node,
                    f"Python branch on a ref value in kernel body "
                    f"{info.qualname}: data-dependent control flow must "
                    "go through pl.when / lax.cond / masking",
                )


def _param_names(fn_node: ast.AST) -> Set[str]:
    args = fn_node.args
    names = {a.arg for a in args.args + args.posonlyargs + args.kwonlyargs}
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    return names


_REF_METADATA = {"shape", "ndim", "dtype", "size"}


def _reads_ref(test: ast.AST, params: Set[str]) -> bool:
    """The branch test loads a ref *value* (``ref[...]``).

    Metadata reads (``ref.shape[-1]``) are static at trace time —
    branching on them is the sanctioned static-guard idiom.
    """
    for sub in ast.walk(test):
        if not isinstance(sub, ast.Subscript):
            continue
        base = sub.value
        if isinstance(base, ast.Attribute) and base.attr in _REF_METADATA:
            continue
        root = root_name(base)
        if root in params or root.endswith("_ref"):
            return True
    return False


@register_rule
class KernelForeignCall(_KernelRule):
    name = "kernel-foreign-call"
    summary = "call outside the blessed surface inside a Pallas kernel body"

    def check_kernel(
        self, module: Module, ctx: AnalysisContext, info: FunctionInfo
    ) -> Iterator[Finding]:
        allowed_local = _local_names(info.node) | _module_level_names(module)
        for node in walk_body(info.node):
            if not isinstance(node, ast.Call):
                continue
            dn = dotted_name(node.func)
            if not dn:
                # method on a computed value (e.g. x[...].sum()): resolve
                # the chain's root name instead
                root = root_name(node.func)
                if root and root not in allowed_local | ALLOWED_ROOTS:
                    yield self._foreign(module, node, root, info)
                continue
            root = dn[0]
            if root in ALLOWED_ROOTS:
                if root == "jax" and len(dn) > 1 and dn[1] == "debug":
                    continue  # kernel-host-callback owns this
                continue
            if root in HOST_ROOTS or (len(dn) == 1 and dn[0] in HOST_CALL_NAMES):
                continue  # kernel-host-callback owns this
            if len(dn) == 1 and (
                root in ALLOWED_BUILTINS or root in allowed_local
            ):
                continue
            if root in allowed_local:
                continue  # method call on a local/module name
            yield self._foreign(module, node, ".".join(dn), info)

    def _foreign(
        self, module: Module, node: ast.Call, what: str, info: FunctionInfo
    ) -> Finding:
        return self.finding(
            module,
            node,
            f"call to {what} inside kernel body {info.qualname} is "
            "outside the blessed surface (jnp/jax.lax/pl/pltpu/module "
            "helpers): it will fail to lower or silently run at trace "
            "time",
        )
